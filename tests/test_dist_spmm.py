"""Distributed multi-RHS spMM + gathered-halo partition tests.

Host-side partition/accounting tests run in-process (they build arrays
but never launch collectives); the end-to-end spMM and block-CG checks
run in a subprocess with 8 virtual host devices, like test_dist_spmv.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import dist_spmv as D, formats as F, matrices as M

pytestmark = pytest.mark.dist


# --------------------------------------------------------------------------
# Host-side: gather sets and communication accounting
# --------------------------------------------------------------------------
def _block_diag_csr(rng, n_dev=8, n_loc=64, n_rows=300):
    """Block-diagonal w.r.t. the n_loc partition of the padded size."""
    n_pad = n_dev * n_loc
    a = np.zeros((n_rows, n_rows), np.float32)
    for p in range(n_dev):
        lo, hi = p * n_loc, min((p + 1) * n_loc, n_rows)
        if hi <= lo:
            break
        blk = rng.standard_normal((hi - lo, hi - lo))
        a[lo:hi, lo:hi] = blk * (rng.random(blk.shape) < 0.3)
    assert n_pad >= n_rows
    return F.csr_from_dense(a)


def _boundary_coupled_csr(rng, n=512, n_loc=64, reach=96, stride=8):
    """Tridiagonal + sparse long-range coupling at ring distance <= 2:
    every ``stride``-th row references column i +/- ``reach``
    (n_loc < reach < 2*n_loc), so only a few columns cross each
    boundary — the regime where the gathered halo wins big."""
    a = np.zeros((n, n), np.float32)
    i = np.arange(n)
    a[i, i] = 4.0
    a[i[:-1], i[:-1] + 1] = -1.0
    a[i[1:], i[1:] - 1] = -1.0
    far = i[::stride]
    for sgn in (+1, -1):
        tgt = far + sgn * reach
        ok = (tgt >= 0) & (tgt < n)
        a[far[ok], tgt[ok]] = -0.5
    return F.csr_from_dense(a)


def test_remote_columns_by_distance():
    """The gather sets are exactly the referenced neighbor columns."""
    # device 1 of 4 (n_loc=4): rows reference cols 0, 2 (dist -1),
    # own slice, and col 9 (dist +1)
    dense = np.zeros((4, 16), np.float32)
    dense[0, [0, 4]] = 1.0
    dense[1, [2, 5, 9]] = 1.0
    dense[3, [0, 7]] = 1.0
    sl = F.csr_from_dense(dense)
    need = F.csr_remote_columns_by_distance(sl, p=1, n_loc=4, n_dev=4)
    assert set(need) == {-1, +1}
    np.testing.assert_array_equal(need[-1], [0, 2])
    np.testing.assert_array_equal(need[+1], [1])   # col 9 -> slice 2, local 1


def test_block_diagonal_measures_zero_halo(rng):
    dist = D.partition_csr(_block_diag_csr(rng), 8, b_r=32)
    assert dist.halo_w == 0
    assert dist.halo_lens == ()
    assert dist.comm_bytes_per_device() == 0
    assert dist.comm_bytes_per_device(halo="full") == 0


def test_comm_bytes_reports_measured_gathered_halo(rng):
    """Satellite: comm_bytes_per_device must report what the wire
    carries, not 2*halo_w*n_loc."""
    m = _boundary_coupled_csr(rng)
    dist = D.partition_csr(m, 8, b_r=32)
    assert dist.halo_w == 2
    gathered = dist.comm_bytes_per_device(value_bytes=4)
    full = dist.comm_bytes_per_device(value_bytes=4, halo="full")
    assert gathered == sum(dist.halo_lens) * 4
    assert full == 2 * 2 * dist.n_loc * 4
    # sparse coupling: the compressed exchange ships far less
    assert gathered * 5 <= full
    # multi-RHS scales both linearly
    assert dist.comm_bytes_per_device(value_bytes=4, k=4) == 4 * gathered


def test_halo_lens_match_gather_sets(rng):
    m = _boundary_coupled_csr(rng)
    n_loc = D.padded_global_size(m.n_rows, 8, 32) // 8
    needs = [
        F.csr_remote_columns_by_distance(
            D._csr_row_slice(m, p * n_loc, (p + 1) * n_loc, n_loc),
            p, n_loc, 8)
        for p in range(8)
    ]
    dist = D.partition_csr(m, 8, b_r=32)
    for i, d in enumerate(D.halo_distances(dist.halo_w)):
        expect = max(len(nd.get(d, ())) for nd in needs)
        assert dist.halo_lens[i] == expect


def test_explicit_halo_w_too_small_raises(rng):
    m = _boundary_coupled_csr(rng)
    with pytest.raises(ValueError, match="halo_w"):
        D.partition_csr(m, 8, b_r=32, halo_w=1)


def test_poisson_partition_matches_tridiag_structure():
    m = M.poisson_2d(40, 40)
    dist = D.partition_csr(m, 8, b_r=32)
    assert dist.halo_w == 1
    # the 5-point stencil couples one grid line (40 cols) per boundary
    assert dist.halo_lens == (40, 40)


# --------------------------------------------------------------------------
# Subprocess: distributed spMM vs dense, and block-CG end-to-end
# --------------------------------------------------------------------------
_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    import repro
    from repro.core import formats as F, matrices as M, dist_spmv as D
    from repro.core.operator import dist_operator
    from repro.launch.mesh import make_host_mesh

    out = {}
    n_dev = 8
    mesh = make_host_mesh(n_dev)
    rng = np.random.default_rng(0)

    def block_diag(n_rows=300, n_loc=64):
        a = np.zeros((n_rows, n_rows), np.float32)
        for p in range(n_dev):
            lo, hi = p * n_loc, min((p + 1) * n_loc, n_rows)
            if hi <= lo:
                break
            blk = rng.standard_normal((hi - lo, hi - lo))
            a[lo:hi, lo:hi] = blk * (rng.random(blk.shape) < 0.3)
        return F.csr_from_dense(a)

    def boundary_coupled(n=512, reach=96, stride=8):
        a = np.zeros((n, n), np.float32)
        i = np.arange(n)
        a[i, i] = 4.0
        a[i[:-1], i[:-1] + 1] = -1.0
        a[i[1:], i[1:] - 1] = -1.0
        far = i[::stride]
        for sgn in (+1, -1):
            tgt = far + sgn * reach
            ok = (tgt >= 0) & (tgt < n)
            a[far[ok], tgt[ok]] = -0.5
        return F.csr_from_dense(a)

    # halo_w 0 / 1 / 2; 300 and 320 are NOT divisible by n_dev*b_r = 256
    cases = [("w0", block_diag(), 0), ("w1", M.poisson_2d(20, 16), 1),
             ("w2", boundary_coupled(), 2)]
    for name, m, w_expect in cases:
        dist = D.partition_csr(m, n_dev, b_r=32)
        out[f"halo_{name}"] = dist.halo_w
        assert dist.halo_w == w_expect, (name, dist.halo_w)
        dense = F.csr_to_dense(m).astype(np.float64)
        for k in (1, 4):
            X = np.zeros((dist.n_global_pad, k), np.float32)
            X[:m.n_rows] = rng.standard_normal((m.n_rows, k))
            Xj = jax.device_put(jnp.asarray(X),
                                jax.NamedSharding(mesh, P("data", None)))
            T = dense @ X[:m.n_rows]
            scale = np.abs(T).max()
            for mode in ("vector", "naive", "overlap"):
                mm = jax.jit(dist_operator(dist, mesh, mode=mode).matmat)
                Y = np.asarray(mm(Xj))[:m.n_rows]
                out[f"err_{name}_k{k}_{mode}"] = float(
                    np.abs(Y - T).max() / scale)
            # gathered and full-slice halos agree
            mm_full = jax.jit(dist_operator(dist, mesh, mode="overlap",
                                            halo="full").matmat)
            Yf = np.asarray(mm_full(Xj))[:m.n_rows]
            out[f"err_{name}_k{k}_full"] = float(np.abs(Yf - T).max() / scale)

    # block-CG on the SPD Poisson system, distributed operator in
    # overlap mode, vs k independent CG solves
    m = M.poisson_2d(20, 16)
    dist = D.partition_csr(m, n_dev, b_r=32)
    k = 4
    B = np.zeros((dist.n_global_pad, k), np.float32)
    B[:m.n_rows] = rng.standard_normal((m.n_rows, k))
    Bj = jax.device_put(jnp.asarray(B),
                        jax.NamedSharding(mesh, P("data", None)))
    op = dist_operator(dist, mesh, mode="overlap")
    res = repro.solve(op, Bj, method="block_cg", maxiter=1500, tol=1e-6)
    out["blk_cg_res"] = float(np.max(np.asarray(res.residual)))
    out["blk_cg_iters"] = int(res.iters)
    Xblk = np.asarray(res.x)[:m.n_rows]

    cg_res, Xcols = [], []
    for j in range(k):
        bj = jax.device_put(jnp.asarray(B[:, j]),
                            jax.NamedSharding(mesh, P("data")))
        r = repro.solve(op, bj, method="cg", maxiter=1500, tol=1e-6)
        cg_res.append(float(r.residual))
        Xcols.append(np.asarray(r.x)[:m.n_rows])
    out["cg_res_max"] = max(cg_res)
    Xind = np.stack(Xcols, axis=1)
    out["x_diff"] = float(np.abs(Xblk - Xind).max() /
                          max(np.abs(Xind).max(), 1e-30))
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def spmm_results():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_spmm_all_modes_all_widths(spmm_results):
    for name in ("w0", "w1", "w2"):
        for k in (1, 4):
            for mode in ("vector", "naive", "overlap"):
                assert spmm_results[f"err_{name}_k{k}_{mode}"] < 1e-5, (
                    name, k, mode)


def test_spmm_gathered_matches_full_slice(spmm_results):
    for name in ("w0", "w1", "w2"):
        for k in (1, 4):
            assert spmm_results[f"err_{name}_k{k}_full"] < 1e-5


def test_measured_halo_widths(spmm_results):
    assert spmm_results["halo_w0"] == 0
    assert spmm_results["halo_w1"] == 1
    assert spmm_results["halo_w2"] == 2


def test_distributed_block_cg_matches_independent_cg(spmm_results):
    """Acceptance: block-CG over the distributed overlap-mode operator
    reaches the same residual as k independent CG solves."""
    assert spmm_results["blk_cg_res"] < 1e-5
    assert spmm_results["cg_res_max"] < 1e-5
    assert spmm_results["x_diff"] < 1e-3
    assert 0 < spmm_results["blk_cg_iters"] < 1500


# --------------------------------------------------------------------------
# Deprecated closure factories (host-side: building warns, no launch)
# --------------------------------------------------------------------------
def test_make_dist_closures_warn():
    """make_dist_matvec/make_dist_matmat are deprecated shims over
    dist_operator — both must raise DeprecationWarning at build time."""
    from repro.launch.mesh import make_host_mesh
    m = M.poisson_2d(8, 8)
    dist = D.partition_csr(m, 1, b_r=32)
    mesh = make_host_mesh(1)
    with pytest.warns(DeprecationWarning, match="dist_operator"):
        D.make_dist_matvec(dist, mesh)
    with pytest.warns(DeprecationWarning, match="dist_operator"):
        D.make_dist_matmat(dist, mesh)
