"""Autotuner unit tests: cache semantics, fingerprint stability, prune
guarantees, calibration, and the tuned end-to-end paths."""
import dataclasses
import json

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import formats as F, matrices as M, perf_model as PM
from repro.kernels import ops
from repro import tune as T


def _mat(n=320, density=0.05, seed=0):
    rng = np.random.default_rng(seed)
    a = ((rng.random((n, n)) < density)
         * rng.standard_normal((n, n))).astype(np.float32)
    return a, F.csr_from_dense(a)


def _model_measure(calls):
    """Deterministic stand-in for the measurement harness: 'measured'
    time = uncalibrated model price (plus a structural epsilon so ties
    break stably), recording every invocation."""
    def fn(m, c, **kw):
        calls.append(c)
        return T.price_candidate(m, c, calibration=None) \
            + (hash(c) % 7) * 1e-9
    return fn


@pytest.fixture
def cache(tmp_path):
    return T.TuneCache(tmp_path / "tune_cache.json")


@pytest.fixture(autouse=True)
def _no_global_calibration():
    yield
    PM.clear_calibration()


# --------------------------------------------------------------- cache
def test_cache_hit_skips_measurement(cache):
    _, m = _mat()
    calls = []
    r1 = T.autotune(m, cache=cache, measure_fn=_model_measure(calls))
    assert not r1.cached and len(calls) > 0
    n_first = len(calls)

    r2 = T.autotune(m, cache=cache, measure_fn=_model_measure(calls))
    assert r2.cached and len(calls) == n_first      # nothing re-measured
    assert r2.best == r1.best and r2.key == r1.key

    r3 = T.autotune(m, cache=cache, measure_fn=_model_measure(calls),
                    force=True)
    assert not r3.cached and len(calls) == 2 * n_first   # force re-measures


def test_cache_survives_reload_and_corruption(cache, tmp_path):
    _, m = _mat()
    r1 = T.autotune(m, cache=cache, measure_fn=_model_measure([]))
    # a fresh instance on the same file sees the entry
    again = T.TuneCache(cache.path)
    assert T.autotune(m, cache=again, measure_fn=_model_measure([])).cached
    # a corrupt file is an empty cache, not an error
    cache.path.write_text("{ not json")
    broken = T.TuneCache(cache.path)
    assert broken.get(r1.key) is None


def test_record_schema_quarantine_round_trip(cache):
    """Individual-record versioning: unknown schema stamps, non-dict
    records and missing required keys QUARANTINE (miss + reason, no
    crash, no silent reuse) and a re-measure ``put`` heals the entry."""
    _, m = _mat()
    r1 = T.autotune(m, cache=cache, measure_fn=_model_measure([]))
    rec = cache.get(r1.key, require=("best",))
    assert rec is not None and rec["schema"] == T.RECORD_SCHEMA

    # hand-mangle the file three ways; a fresh loader quarantines each
    payload = json.loads(cache.path.read_text())
    entries = payload["entries"]
    good = entries[r1.key]
    entries[r1.key] = {**good, "schema": 999}      # future version
    entries["k_str"] = "not a dict"
    entries["k_bare"] = {"schema": T.RECORD_SCHEMA}
    cache.path.write_text(json.dumps(payload))

    fresh = T.TuneCache(cache.path)
    assert fresh.get(r1.key) is None
    assert "schema" in fresh.quarantined[r1.key]
    assert fresh.get("k_str") is None
    assert "dict" in fresh.quarantined["k_str"]
    assert fresh.get("k_bare", require=("best",)) is None
    assert "missing" in fresh.quarantined["k_bare"]
    assert fresh.get("k_bare") is not None         # stamp alone is valid

    # the autotuner degrades to a re-measure, then the put heals it
    calls = []
    r2 = T.autotune(m, cache=fresh, measure_fn=_model_measure(calls))
    assert not r2.cached and calls
    assert r1.key not in fresh.quarantined
    assert fresh.get(r1.key, require=("best",)) is not None


def test_malformed_nested_record_quarantines(cache):
    """A record with a valid stamp but garbage INSIDE the required key
    (deserialization blows up) also degrades to a re-measure."""
    _, m = _mat()
    r1 = T.autotune(m, cache=cache, measure_fn=_model_measure([]))
    payload = json.loads(cache.path.read_text())
    payload["entries"][r1.key]["best"] = 42      # breaks from_dict
    cache.path.write_text(json.dumps(payload))

    fresh = T.TuneCache(cache.path)
    calls = []
    r2 = T.autotune(m, cache=fresh, measure_fn=_model_measure(calls))
    assert not r2.cached and calls                 # degraded to re-measure
    # ... and the re-measure's put healed the record in place
    assert r1.key not in fresh.quarantined
    healed = fresh.get(r1.key, require=("best",))
    assert isinstance(healed["best"], dict)


def test_cache_key_separates_policy_device_format():
    fp = "f" * 40
    keys = {
        T.cache_key(fp, "cpu:x", T.dtype_policy(None, "auto")),
        T.cache_key(fp, "tpu:v5e", T.dtype_policy(None, "auto")),
        T.cache_key(fp, "cpu:x", T.dtype_policy(jnp.bfloat16, "auto")),
        T.cache_key(fp, "cpu:x", T.dtype_policy(None, np.int32)),
        T.cache_key(fp, "cpu:x", T.dtype_policy(None, "auto"), "fmt=sell"),
    }
    assert len(keys) == 5


# --------------------------------------------------------- fingerprint
def test_fingerprint_stable_under_value_changes():
    _, m = _mat(seed=3)
    m2 = F.CSRMatrix(m.indptr.copy(), m.indices.copy(),
                     m.data * 7.5 + 1.0, m.shape)
    assert F.structural_fingerprint(m) == F.structural_fingerprint(m2)


def test_fingerprint_invalidates_under_structure_changes():
    _, m = _mat(seed=4)
    fp = F.structural_fingerprint(m)
    # add one entry (same values elsewhere)
    rows = np.repeat(np.arange(m.n_rows), m.row_lengths())
    m_plus = F.csr_from_coo(np.concatenate([rows, [0]]),
                            np.concatenate([m.indices, [m.n_cols - 1]]),
                            np.concatenate([m.data, [1.0]]), m.shape)
    assert F.structural_fingerprint(m_plus) != fp
    # same pattern, different shape
    m_wide = F.CSRMatrix(m.indptr, m.indices, m.data,
                         (m.shape[0], m.shape[1] + 1))
    assert F.structural_fingerprint(m_wide) != fp


# ----------------------------------------------------- space / pruning
@pytest.mark.parametrize("mk", [
    lambda: _mat(256, 0.03, 1)[1],
    lambda: M.samg(scale=0.002),
    lambda: M.power_law(1024, seed=7),
    lambda: M.poisson_2d(16, 16),
])
def test_pruning_never_drops_heuristic(mk):
    m = mk()
    heur = T.heuristic_candidate(m)
    pruned = T.prune_candidates(m, T.enumerate_candidates(m), top_k=3)
    assert heur in pruned
    assert len(pruned) <= 4      # top_k + (possibly) the appended heuristic


def test_enumerate_respects_format_restriction():
    _, m = _mat()
    cands = T.enumerate_candidates(m, format="pjds")
    assert {c.fmt for c in cands} <= {"pjds"}
    assert T.heuristic_candidate(m, format="pjds") in cands


def test_degenerate_matrix_collapses_to_csr():
    a = np.zeros((8, 8), np.float32)
    m = F.csr_from_dense(a)
    cands = T.enumerate_candidates(m)
    assert all(c.fmt == "csr" for c in cands)


def test_candidate_json_roundtrip():
    c = T.Candidate(fmt="sell", b_r=64, chunk_l=8, sigma=512, x_tiles=2)
    assert T.Candidate.from_dict(json.loads(json.dumps(c.as_dict()))) == c


# ---------------------------------------------------------- calibration
def test_calibration_strictly_improves_synthetic():
    rng = np.random.default_rng(5)
    rows = []
    for i in range(30):
        fmt = ("pjds", "sell", "ellpack_r")[i % 3]
        # model times spanning 3 decades so both the scale (large rows)
        # and the per-format offset (small rows) are identifiable
        model = float(10 ** rng.uniform(-7, -4))
        true = model / 0.002 + {"pjds": 2e-4, "sell": 5e-5,
                                "ellpack_r": 0.0}[fmt]
        rows.append(dict(fmt=fmt, model_s=model,
                         measured_s=true * float(rng.uniform(0.99, 1.01))))
    err0 = T.model_error(rows)
    cal = T.fit_calibration(rows, source="synthetic")
    err1 = T.model_error(rows, cal)
    assert err1 < err0               # strict improvement
    assert err1 < 0.1                # and actually a good fit
    assert cal.bw_scale == pytest.approx(0.002, rel=0.5)
    assert cal.overhead_s.get("pjds", 0) == pytest.approx(2e-4, rel=0.5)
    assert cal.overhead_s.get("pjds", 0) > cal.overhead_s.get("sell", 0)


def test_calibration_installs_into_predicted_seconds():
    t0 = PM.predicted_spmv_seconds(10_000, 1_000, 10.0, fmt="pjds")
    cal = PM.Calibration(bw_scale=0.5, overhead_s={"pjds": 1e-3})
    PM.set_calibration(cal)
    t1 = PM.predicted_spmv_seconds(10_000, 1_000, 10.0, fmt="pjds")
    assert t1 == pytest.approx(2 * t0 + 1e-3)
    # explicit calibration=None bypasses the installed one
    assert PM.predicted_spmv_seconds(10_000, 1_000, 10.0, fmt="pjds",
                                     calibration=None) == pytest.approx(t0)
    PM.clear_calibration()
    assert PM.predicted_spmv_seconds(10_000, 1_000, 10.0,
                                     fmt="pjds") == pytest.approx(t0)


def test_calibration_improves_on_measured_rows(cache):
    """End-to-end: fit on real measured autotune rows -> the calibrated
    model error on those rows is strictly below the uncalibrated one."""
    _, m = _mat(256, 0.08, 6)
    res = T.autotune(m, cache=cache, warmup=1, iters=3)
    err0 = T.model_error(res.rows)
    cal = T.fit_calibration(res.rows)
    assert T.model_error(res.rows, cal) < err0


def test_rows_from_bench_kernels(tmp_path):
    payload = {"suite": "kernels", "rows": [
        {"kind": "bytes_per_nnz", "fmt": "pjds", "predicted_s": 1e-5,
         "measured_ref_s": 3e-4},
        {"kind": "padding", "b_r": 32},
        {"kind": "bytes_per_nnz", "fmt": "sell", "predicted_s": 2e-5,
         "measured_ref_s": 5e-4},
    ]}
    p = tmp_path / "BENCH_kernels.json"
    p.write_text(json.dumps(payload))
    rows = T.rows_from_bench_kernels(p)
    assert [r["fmt"] for r in rows] == ["pjds", "sell"]
    cal = T.fit_from_bench_kernels(p)
    assert T.model_error(rows, cal) < T.model_error(rows)


def test_link_calibration_recovers_synthetic():
    """fit_link_calibration identifies the per-message fixed cost and
    the effective link bandwidth from bulk-synchronous rows built with
    known ground truth, and strictly improves link_model_error."""
    rng = np.random.default_rng(11)
    base = {"g1": 120e-6, "g2": 210e-6}
    cost = {"gathered": 25e-6, "full": 5e-6}
    inv_bw = 1.0 / (PM.TPU_V5E.ici_bw * 0.5)       # link_bw_scale = 0.5
    rows = []
    for group in base:
        for halo in cost:
            for msgs, byts in ((2, 4e5), (4, 1.6e6), (6, 6.4e6)):
                t = base[group] + msgs * cost[halo] + byts * inv_bw
                rows.append(dict(group=group, halo=halo, msgs=msgs,
                                 bytes=byts,
                                 measured_s=t * rng.uniform(0.99, 1.01)))
    err0 = T.link_model_error(rows)
    cal = T.fit_link_calibration(rows, source="synthetic")
    err1 = T.link_model_error(rows, cal)
    assert err1 < err0 and err1 < 0.05
    assert cal.msg_overhead_s["gathered"] == pytest.approx(25e-6, rel=0.5)
    assert cal.msg_overhead_s["gathered"] > cal.msg_overhead_s.get("full", 0)
    assert cal.link_bw_scale == pytest.approx(0.5, rel=0.5)
    assert cal.source == "synthetic"


def test_link_calibration_rejects_bad_rows():
    with pytest.raises(ValueError):
        T.fit_link_calibration([])
    with pytest.raises(ValueError):
        T.fit_link_calibration([dict(group="g", halo="full", msgs=2,
                                     bytes=100, measured_s=0.0)])


def test_dist_candidates_enumeration():
    cands = T.dist_candidates(8)
    assert all(set(c) == {"grid", "halo", "mode", "halo_w"} for c in cands)
    # 1-D row partitioning is stored as grid=None
    assert any(c["grid"] is None for c in cands)
    grids = {c["grid"] for c in cands}
    assert {(1, 8), (2, 4), (4, 2)} <= grids
    # naive is dominated; a staged full exchange cannot win
    assert not any(c["mode"] == "naive" for c in cands)
    assert not any(c["mode"] == "pipeline" and c["halo"] == "full"
                   for c in cands)
    # pipeline+gathered survives — it is the tentpole configuration
    assert any(c["mode"] == "pipeline" and c["halo"] == "gathered"
               for c in cands)
    # deduped
    keys = [tuple(sorted(c.items(), key=lambda kv: kv[0])) for c in cands]
    assert len(keys) == len(set(keys))
    # degenerate mesh still enumerates
    assert all(c["grid"] is None for c in T.dist_candidates(1))


# ------------------------------------------------- end-to-end threading
def test_as_device_tune_auto_builds_tuned_statics(cache, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache.path))
    a, m = _mat(256, 0.05, 7)
    calls = []
    # seed the persistent cache through the injected harness, then let
    # as_device pick the decision up from disk
    res = T.autotune(m, cache=T.TuneCache(cache.path),
                     measure_fn=_model_measure(calls))
    sd = ops.as_device(m, tune="auto")
    assert sd.fmt == res.best.fmt
    d = sd.dev
    if res.best.fmt in ("pjds", "sell"):
        assert d.b_r == res.best.b_r and d.chunk_l == res.best.chunk_l
    x = np.random.default_rng(1).standard_normal(m.shape[1]).astype(np.float32)
    truth = a.astype(np.float64) @ x
    from repro.core.operator import operator
    y = np.asarray(operator(m, tune="auto") @ jnp.asarray(x), np.float64)
    scale = max(np.abs(truth).max(), 1.0)
    np.testing.assert_allclose(y / scale, truth / scale, atol=1e-5)


def test_operator_tune_auto_parity(cache, monkeypatch):
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(cache.path))
    from repro.core.operator import operator
    a, m = _mat(192, 0.06, 8)
    T.autotune(m, cache=T.TuneCache(cache.path),
               measure_fn=_model_measure([]))
    op = operator(m, tune="auto")
    x = np.random.default_rng(2).standard_normal(m.shape[1]).astype(np.float32)
    truth = a.astype(np.float64) @ x
    scale = max(np.abs(truth).max(), 1.0)
    np.testing.assert_allclose(np.asarray(op @ jnp.asarray(x)) / scale,
                               truth / scale, atol=1e-5)


def test_bad_tune_value_raises():
    _, m = _mat(64, 0.1, 9)
    with pytest.raises(ValueError):
        ops.as_device(m, tune="always")


# ------------------------------------------------------- partition tune
def test_tune_partition_independent_and_cached(cache):
    m = M.poisson_2d(24, 24)
    tp = T.tune_partition(m, 4, b_r=32, cache=cache, iters=2)
    assert not tp.cached
    assert tp.chunk_l in (8, 16, 32) and tp.rem_chunk_l in (8, 16, 32)
    operands = {r["operand"] for r in tp.rows}
    assert operands == {"loc", "rem"}        # both measured, independently
    tp2 = T.tune_partition(m, 4, b_r=32, cache=cache, iters=2)
    assert tp2.cached and (tp2.chunk_l, tp2.rem_chunk_l) == \
        (tp.chunk_l, tp.rem_chunk_l)
    # a different geometry is a different cache entry
    tp3 = T.tune_partition(m, 2, b_r=32, cache=cache, iters=2)
    assert not tp3.cached


def test_partition_rem_chunk_l_matches_shared_build():
    """rem_chunk_l == chunk_l must reproduce the shared-tile partition
    bit-for-bit (the tuned path degenerates cleanly)."""
    from repro.core import dist_spmv as D
    m = M.poisson_2d(16, 16)
    d_shared = D.partition_csr(m, 2, b_r=32, chunk_l=8)
    d_tuned = D.partition_csr(m, 2, b_r=32, chunk_l=8, rem_chunk_l=8)
    assert d_tuned.rem_chunk_l is None       # canonicalised
    np.testing.assert_array_equal(np.asarray(d_shared.rem_val),
                                  np.asarray(d_tuned.rem_val))
    np.testing.assert_array_equal(np.asarray(d_shared.rem_chunk_map),
                                  np.asarray(d_tuned.rem_chunk_map))


# --------------------------------------------------------------- solver tune
def _solver_measure(calls, fused_s=1e-6, composed_s=2e-6):
    """Injected stand-in for measure_solver_candidate: fused always wins,
    every invocation recorded."""
    def fn(m, strategy, c, **kw):
        calls.append((strategy, c.label()))
        return (fused_s if strategy == "fused" else composed_s) \
            + (hash((strategy, c)) % 7) * 1e-12
    return fn


def test_tune_solver_cached_under_method_key(cache):
    m = M.poisson_2d(16, 16)
    calls = []
    st1 = T.tune_solver(m, method="cg", cache=cache,
                        measure_fn=_solver_measure(calls))
    assert not st1.cached and len(calls) > 0
    assert st1.strategy == "fused"           # the injected winner
    assert {s for s, _ in calls} == {"fused", "composed"}
    n_first = len(calls)

    st2 = T.tune_solver(m, method="cg", cache=cache,
                        measure_fn=_solver_measure(calls))
    assert st2.cached and len(calls) == n_first      # nothing re-measured
    assert (st2.strategy, st2.layout) == (st1.strategy, st1.layout)
    assert st2.key == st1.key

    # the method is part of the cache key: bicgstab tunes independently
    st3 = T.tune_solver(m, method="bicgstab", cache=cache,
                        measure_fn=_solver_measure(calls))
    assert not st3.cached and st3.key != st1.key

    # force re-measures through the same key
    st4 = T.tune_solver(m, method="cg", cache=cache, force=True,
                        measure_fn=_solver_measure(calls))
    assert not st4.cached and st4.key == st1.key


def test_tune_solver_picks_composed_when_it_wins(cache):
    m = M.poisson_2d(12, 12)
    st = T.tune_solver(m, method="cg", cache=cache,
                       measure_fn=_solver_measure([], fused_s=5e-6,
                                                  composed_s=1e-6))
    assert st.strategy == "composed"
    # every row records (strategy, layout, seconds) for diagnostics
    assert all({"strategy", "layout", "seconds_per_iter"} <= set(r)
               for r in st.rows)
