"""Property-based format roundtrip tests (hypothesis; the deterministic
``_compat`` fallback stands in when the real package is absent).

The invariant under test is the foundation everything else builds on:
for ANY csr matrix, converting to each blocked format and densifying
recovers exactly the dense matrix the CSR describes — including the
structures the converters' padding logic finds hardest (empty rows,
all-zero matrices, row-length cliffs) and the index-compression
boundary (column spans straddling 2**15, where ``index_dtype="auto"``
flips between int16 and int32).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import formats as F

INT16_SPAN = 2 ** 15          # resolve_index_dtype's int16/int32 boundary


def _random_dense(seed, n, pattern, empty_frac):
    """Small random square matrix with structurally diverse sparsity."""
    rng = np.random.default_rng(seed)
    if pattern == "banded":
        d = np.abs(np.subtract.outer(np.arange(n), np.arange(n)))
        a = np.where(d <= 5, rng.standard_normal((n, n)), 0.0)
    elif pattern == "powerlaw":
        rl = np.clip(rng.zipf(1.7, size=n), 1, max(n // 4, 2))
        a = np.zeros((n, n))
        for i in range(n):
            a[i, rng.integers(0, n, size=rl[i])] = rng.standard_normal(rl[i])
    else:
        a = (rng.random((n, n)) < 0.07) * rng.standard_normal((n, n))
    # force a block of EMPTY rows (the padding paths must represent them)
    n_empty = int(empty_frac * n)
    if n_empty:
        a[rng.choice(n, size=n_empty, replace=False)] = 0.0
    return a.astype(np.float32)


def _roundtrip_all(a, b_r, sigma_factor):
    """csr -> {ellr, pjds, sell} -> dense must equal csr -> dense."""
    m = F.csr_from_dense(a)
    dense = F.csr_to_dense(m)
    np.testing.assert_array_equal(dense, a)

    e = F.csr_to_ell(m, row_align=b_r, diag_align=8)
    np.testing.assert_array_equal(F.ell_to_dense(e), a)

    square = m.shape[0] == m.shape[1]
    for permuted_cols in ((False, True) if square else (False,)):
        p = F.csr_to_pjds(m, b_r=b_r, permuted_cols=permuted_cols)
        np.testing.assert_array_equal(F.pjds_to_dense(p), a)
        s = F.csr_to_sell(m, c=b_r, sigma=sigma_factor * b_r,
                          permuted_cols=permuted_cols)
        np.testing.assert_array_equal(F.sell_to_dense(s), a)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       n=st.sampled_from([17, 48, 96, 130]),
       pattern=st.sampled_from(["banded", "powerlaw", "uniform"]),
       empty_frac=st.sampled_from([0.0, 0.2]),
       b_r=st.sampled_from([8, 16, 32]),
       sigma_factor=st.sampled_from([1, 4]))
def test_roundtrip_random(seed, n, pattern, empty_frac, b_r, sigma_factor):
    _roundtrip_all(_random_dense(seed, n, pattern, empty_frac),
                   b_r, sigma_factor)


@settings(max_examples=8, deadline=None)
@given(n=st.sampled_from([3, 16, 40]), b_r=st.sampled_from([8, 32]))
def test_roundtrip_all_zero(n, b_r):
    """nnz == 0: every converter must still build (padding floors at one
    jagged diagonal) and densify back to zeros."""
    a = np.zeros((n, n), np.float32)
    _roundtrip_all(a, b_r, sigma_factor=4)
    m = F.csr_from_dense(a)
    assert m.nnz == 0
    assert F.storage_elements(F.csr_to_pjds(m, b_r=b_r)) > 0   # padded, legal


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n=st.sampled_from([24, 64]))
def test_roundtrip_trailing_empty_rows(seed, n):
    """Rows past the last nonzero row: the indptr tail is flat and the
    converters' per-row loops must not read past it."""
    rng = np.random.default_rng(seed)
    a = np.zeros((n, n), np.float32)
    a[: n // 3] = ((rng.random((n // 3, n)) < 0.2)
                   * rng.standard_normal((n // 3, n))).astype(np.float32)
    _roundtrip_all(a, b_r=8, sigma_factor=1)


@settings(max_examples=10, deadline=None)
@given(offset=st.sampled_from([-2, -1, 0, 1, 2]),
       n_rows=st.sampled_from([4, 11]))
def test_single_column_span_at_int16_boundary(offset, n_rows):
    """All nonzeros in ONE column whose position straddles the int16
    addressability boundary: ``index_dtype="auto"`` must pick int16
    exactly when the span fits 2**15 and the roundtrip must be exact
    either way (the compressed index stream loses nothing)."""
    col = INT16_SPAN - 1 + offset
    n_cols = col + 1
    rows = np.arange(n_rows, dtype=np.int64)
    vals = np.arange(1, n_rows + 1, dtype=np.float32)
    m = F.csr_from_coo(rows, np.full(n_rows, col), vals, (n_rows, n_cols))

    expect = np.dtype(np.int16) if n_cols <= INT16_SPAN else np.dtype(np.int32)
    assert F.min_index_dtype(n_cols) == expect

    e = F.csr_to_ell(m, row_align=8, diag_align=8)
    assert e.col_idx.dtype == expect
    dense = F.ell_to_dense(e)
    assert dense.shape == (n_rows, n_cols)
    np.testing.assert_array_equal(dense[:, col], vals)
    assert np.count_nonzero(dense) == n_rows

    p = F.csr_to_pjds(m, b_r=8, permuted_cols=False)
    assert p.col_idx.dtype == expect
    np.testing.assert_array_equal(F.pjds_to_dense(p), dense)

    s = F.csr_to_sell(m, c=8, sigma=8, permuted_cols=False)
    assert s.pjds.col_idx.dtype == expect
    np.testing.assert_array_equal(F.sell_to_dense(s), dense)


def test_explicit_index_dtype_narrowing_is_an_error():
    """A lossy explicit narrowing must raise at build time, never wrap."""
    m = F.csr_from_coo([0], [INT16_SPAN], [1.0], (4, INT16_SPAN + 1))
    with pytest.raises(ValueError):
        F.csr_to_ell(m, index_dtype=np.int16)
    with pytest.raises(ValueError):
        F.resolve_index_dtype(np.uint16, 10)      # unsigned rejected too
