"""Operator registry: fingerprint-keyed admission, shared tune cache,
zero-reconversion value swaps, LRU bounds, collision safety."""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import formats as F
from repro.core import matrices as M
from repro.serve import OperatorRegistry, RegistryMismatch
from repro.tune.cache import TuneCache


def _counting_measure():
    calls = {"n": 0}

    def fake(m, c, **kw):
        calls["n"] += 1
        return 1e-3 + 1.0 / (c.b_r * c.chunk_l)

    return calls, fake


def test_cold_admit_measures_warm_admit_does_not(tmp_path):
    """The zero-warmup contract: a structure tuned ONCE (by any
    registry sharing the persistent cache) admits everywhere else with
    zero tuning measurements — the fingerprint key is shared between
    the registry and the tune cache by construction."""
    calls, fake = _counting_measure()
    cache = TuneCache(tmp_path / "tune.json")
    reg = OperatorRegistry(tune="auto", cache=cache, measure_fn=fake)
    e = reg.admit(M.poisson_2d(10, 10))
    assert calls["n"] > 0
    assert e.tune_info["cached"] is False

    # a NEW registry + NEW cache object over the SAME file: still zero
    calls["n"] = 0
    reg2 = OperatorRegistry(tune="auto",
                            cache=TuneCache(tmp_path / "tune.json"),
                            measure_fn=fake)
    e2 = reg2.admit(M.poisson_2d(10, 10))
    assert calls["n"] == 0
    assert e2.tune_info["cached"] is True
    assert e2.key == e.key


def test_warm_admit_same_values_is_pure_lookup():
    reg = OperatorRegistry(tune="off")
    m = M.poisson_2d(8, 8)
    e = reg.admit(m)
    op_before = e.op
    e2 = reg.admit(M.poisson_2d(8, 8))      # fresh object, equal bytes
    assert e2 is e
    assert e2.op is op_before               # no rebuild, no swap
    assert e2.hits == 1 and e2.swaps == 0


def test_value_swap_is_zero_reconversion(rng):
    """New coefficients on a resident structure swap through the value
    map: the operator's answers update, its STRUCTURE leaves are the
    very same arrays (no format reconversion happened), and tuned
    statics survive because the fingerprint did not change."""
    reg = OperatorRegistry(tune="off")
    m = M.poisson_2d(10, 10)
    e = reg.admit(m)
    inner_before = e.op.dev.dev

    m2 = dataclasses.replace(
        m, data=(m.data * rng.uniform(1.5, 2.5)).astype(m.data.dtype))
    assert F.structural_fingerprint(m2) == e.key
    e2 = reg.admit(m2)
    assert e2 is e and e.swaps == 1 and e.version == 1

    # structure leaves are SHARED BY IDENTITY with the pre-swap operand
    inner_after = e.op.dev.dev
    val_fields = ("val", "data")
    shared = 0
    for f in dataclasses.fields(inner_after):
        if f.name in val_fields:
            continue
        a, b = getattr(inner_after, f.name), getattr(inner_before, f.name)
        if hasattr(a, "shape"):
            assert a is b, f"structure leaf {f.name} was rebuilt"
            shared += 1
    assert shared >= 1

    # and the swapped operator computes with the NEW values
    x = rng.standard_normal(m.shape[1]).astype(np.float32)
    y = np.asarray(e.op @ jnp.asarray(x))
    np.testing.assert_allclose(y, m2.matvec(x), rtol=1e-5, atol=1e-5)


def test_value_swap_solves_to_new_answers(rng):
    import repro
    reg = OperatorRegistry(tune="off")
    m = M.poisson_2d(8, 8)
    e = reg.admit(m)
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    x1 = np.asarray(repro.solve(e.op, jnp.asarray(b), tune="off").x)

    m2 = dataclasses.replace(m, data=(m.data * 3.0).astype(m.data.dtype))
    reg.admit(m2)
    x2 = np.asarray(repro.solve(e.op, jnp.asarray(b), tune="off").x)
    np.testing.assert_allclose(x2, x1 / 3.0, rtol=1e-4, atol=1e-5)


def test_lru_eviction_bounds_residency():
    reg = OperatorRegistry(capacity=2, tune="off")
    e1 = reg.admit(M.poisson_2d(6, 6))
    e2 = reg.admit(M.poisson_2d(7, 7))
    reg.get(e1.key)                          # touch: e1 most-recent
    e3 = reg.admit(M.poisson_2d(8, 8))      # evicts e2 (LRU), not e1
    assert len(reg) == 2 and reg.evictions == 1
    assert e1.key in reg and e3.key in reg and e2.key not in reg
    # evicted structures may re-admit (fresh entry)
    e2b = reg.admit(M.poisson_2d(7, 7))
    assert e2b.key == e2.key and e2b is not e2


def test_fingerprint_hit_with_mismatched_dtype_policy_rejected():
    reg = OperatorRegistry(tune="off")
    m = M.poisson_2d(8, 8)
    reg.admit(m)                             # native policy
    with pytest.raises(RegistryMismatch, match="dtype"):
        reg.admit(M.poisson_2d(8, 8), dtype=jnp.bfloat16)
    # the resident entry is untouched
    assert reg.get(F.structural_fingerprint(m)).policy == "native+auto"


def test_fingerprint_hit_with_mismatched_shape_rejected():
    """A sha1 collision cannot be manufactured, so tamper with the
    resident entry's recorded contract: the guard must refuse to serve
    a structure whose shape/nnz contradicts the hit."""
    reg = OperatorRegistry(tune="off")
    m = M.poisson_2d(8, 8)
    e = reg.admit(m)
    e.shape = (3, 3)
    with pytest.raises(RegistryMismatch, match="structure"):
        reg.admit(M.poisson_2d(8, 8))
    e.shape = tuple(m.shape)
    e.nnz = 1
    with pytest.raises(RegistryMismatch, match="structure"):
        reg.admit(M.poisson_2d(8, 8))


def test_opaque_entry_cannot_serve_host_admissions():
    from repro.core.operator import operator
    reg = OperatorRegistry(tune="off")
    m = M.poisson_2d(8, 8)
    reg.admit_operator(operator(m, b_r=32), key=F.structural_fingerprint(m))
    with pytest.raises(RegistryMismatch):
        reg.admit(m)


def test_admit_rejects_non_host_inputs():
    from repro.core.operator import operator
    reg = OperatorRegistry(tune="off")
    with pytest.raises(TypeError, match="admit_operator"):
        reg.admit(operator(M.poisson_2d(6, 6), b_r=32))


def test_stats_shape():
    reg = OperatorRegistry(capacity=2, tune="off")
    reg.admit(M.poisson_2d(6, 6))
    st = reg.stats()
    assert st["resident"] == 1 and st["capacity"] == 2
    assert st["entries"][0]["nnz"] == M.poisson_2d(6, 6).nnz
