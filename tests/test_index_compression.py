"""Compressed-stream edge cases: int16/int32 index selection at the
boundary, bf16 value storage vs the f32 reference, accumulator dtypes,
the padding-sentinel audit, and the column-blocked-x kernel grid."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import formats as F
from repro.core.operator import operator
from repro.kernels import ops


def _mk(rng, n, density=0.08, n_cols=None, dtype=np.float32):
    n_cols = n if n_cols is None else n_cols
    a = ((rng.random((n, n_cols)) < density)
         * rng.standard_normal((n, n_cols))).astype(dtype)
    return a, F.csr_from_dense(a)


# --------------------------------------------------------------- selection
def test_min_index_dtype_boundary():
    assert F.min_index_dtype(1) == np.int16
    assert F.min_index_dtype(2 ** 15) == np.int16       # max col 32767 fits
    assert F.min_index_dtype(2 ** 15 + 1) == np.int32   # col 32768 does not


def test_resolve_index_dtype_rejects_lossy_narrowing():
    assert F.resolve_index_dtype("auto", 100) == np.int16
    assert F.resolve_index_dtype(np.int32, 100) == np.int32  # explicit wide ok
    with pytest.raises(ValueError):
        F.resolve_index_dtype(np.int16, 2 ** 15 + 1)
    with pytest.raises(ValueError):
        F.resolve_index_dtype(np.uint16, 100)           # signed only


def test_builders_compress_at_boundary(rng):
    # wide-but-sparse matrices via COO keep the build cheap
    rows = np.arange(64, dtype=np.int64).repeat(3)
    vals = rng.standard_normal(len(rows))
    for span, want in ((2 ** 15, np.int16), (2 ** 15 + 1, np.int32)):
        cols = rng.integers(0, span, len(rows))
        m = F.csr_from_coo(rows, cols, vals, (64, span))
        e = F.csr_to_ell(m, row_align=32)
        p = F.csr_to_pjds(m, b_r=32, permuted_cols=False)
        assert e.col_idx.dtype == want
        assert p.col_idx.dtype == want
    # the permuted-cols build addresses the PADDED ROW span, not n_cols
    sq = F.csr_from_coo(rows, rng.integers(0, 64, len(rows)), vals, (64, 64))
    assert F.csr_to_pjds(sq, b_r=32, permuted_cols=True).col_idx.dtype \
        == np.int16


# ----------------------------------------------------- end-to-end numerics
@pytest.mark.parametrize("n", [96, 130, 161])   # incl. non-divisible rows
@pytest.mark.parametrize("fmt", ["ellpack_r", "pjds", "sell"])
def test_int16_matches_int32_and_dense(rng, n, fmt):
    a, m = _mk(rng, n)
    x = rng.standard_normal(n).astype(np.float32)
    truth = a.astype(np.float64) @ x
    y16 = np.asarray(operator(m, format=fmt, b_r=32,
                              backend="kernel") @ x)
    y32 = np.asarray(operator(m, format=fmt, b_r=32, backend="kernel",
                              index_dtype=np.int32) @ x)
    d16 = ops.as_device(m, fmt, b_r=32)
    assert d16.index_dtype == np.int16        # n << 2**15: auto compresses
    assert ops.as_device(m, fmt, b_r=32,
                         index_dtype=np.int32).index_dtype == np.int32
    np.testing.assert_allclose(y16, y32, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(y16, truth, atol=1e-3)


@pytest.mark.parametrize("fmt", ["pjds", "sell"])
def test_bf16_storage_numerics_and_dtype(rng, fmt):
    a, m = _mk(rng, 160)
    x = rng.standard_normal(160).astype(np.float32)
    truth = a.astype(np.float64) @ x
    dev = ops.as_device(m, fmt, b_r=32, dtype=jnp.bfloat16)
    assert dev.value_dtype == jnp.bfloat16
    assert dev.index_dtype == np.int16
    for backend in ("ref", "kernel"):
        y = dev.matvec(jnp.asarray(x), backend=backend)
        # bf16 storage, f32 accumulation — and an f32 result
        assert y.dtype == jnp.float32
        scale = max(np.abs(truth).max(), 1.0)
        err = np.abs(np.asarray(y, np.float64) - truth) / scale
        assert err.max() < 1e-2               # the acceptance tolerance


def test_bf16_matmat_accumulates_f32(rng):
    a, m = _mk(rng, 128)
    dev = ops.as_device(m, "sell", b_r=32, dtype=jnp.bfloat16)
    xs = rng.standard_normal((128, 8)).astype(np.float32)
    ys = dev.matmat(jnp.asarray(xs), backend="kernel")
    assert ys.dtype == jnp.float32
    truth = a.astype(np.float64) @ xs
    scale = max(np.abs(truth).max(), 1.0)
    assert (np.abs(np.asarray(ys, np.float64) - truth) / scale).max() < 1e-2


# --------------------------------------------------------- padding sentinel
def test_padding_audit_passes_on_built_formats(rng):
    _, m = _mk(rng, 130, density=0.15)
    F.assert_padding_invariant(F.csr_to_ell(m, row_align=32))
    F.assert_padding_invariant(F.csr_to_pjds(m, b_r=32, permuted_cols=False))
    F.assert_padding_invariant(F.csr_to_sell(m, c=32, permuted_cols=False))


def test_padding_audit_catches_corruption(rng):
    _, m = _mk(rng, 130, density=0.05)
    p = F.csr_to_pjds(m, b_r=32, permuted_cols=False)
    # the very last storage slot of the last block belongs to the padded
    # (shortest, possibly empty) row of the sorted order
    assert p.rowlen[-1] < p.block_len[-1]
    bad_val = p.val.copy()
    bad_val[-1, -1] = 7.0
    with pytest.raises(AssertionError):
        F.assert_padding_invariant(
            F.PJDSMatrix(**{**p.__dict__, "val": bad_val}))
    bad_col = p.col_idx.copy()
    bad_col[-1, -1] = 3
    with pytest.raises(AssertionError):
        F.assert_padding_invariant(
            F.PJDSMatrix(**{**p.__dict__, "col_idx": bad_col}))


# ------------------------------------------------------- column-blocked x
@pytest.mark.parametrize("x_tiles", [2, 4])
@pytest.mark.parametrize("fmt", ["pjds", "sell"])
def test_x_tiled_kernel_matches_resident(rng, fmt, x_tiles):
    a, m = _mk(rng, 128, density=0.1)
    x = rng.standard_normal(128).astype(np.float32)
    y_res = np.asarray(operator(m, format=fmt, b_r=32, backend="kernel",
                                x_tiles=1) @ x)
    y_tiled = np.asarray(operator(m, format=fmt, b_r=32,
                                  backend="kernel", x_tiles=x_tiles) @ x)
    np.testing.assert_allclose(y_tiled, y_res, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(y_tiled, a.astype(np.float64) @ x, atol=1e-3)


def test_x_tiles_pad_when_not_divisible(rng):
    # 130-column x with x_tiles=4: the kernel pads x internally to a
    # tile multiple and still tiles (no silent resident fallback)
    a, m = _mk(rng, 130, density=0.1)
    x = rng.standard_normal(130).astype(np.float32)
    for fmt in ("pjds", "sell"):
        y = np.asarray(operator(m, format=fmt, b_r=32, backend="kernel",
                                x_tiles=4) @ x)
        np.testing.assert_allclose(y, a.astype(np.float64) @ x, atol=1e-3)


def test_choose_x_tiles_budget():
    assert ops.choose_x_tiles(1024, 4) == 1              # fits: resident
    assert ops.choose_x_tiles(1024, 4, vmem_limit=1024) == 4
    assert ops.choose_x_tiles(4096, 2, vmem_limit=1024) == 8


def test_auto_format_avoids_resident_kernels_when_x_tiled(rng):
    # near-constant rows would normally short-circuit to ellpack_r, whose
    # kernel keeps x resident; with x tiling required, auto must pick a
    # format whose kernel can column-block the RHS
    a = np.zeros((256, 256), np.float32)
    for i in range(256):
        a[i, rng.integers(0, 256, 8)] = 1.0
    m = F.csr_from_dense(a)
    assert ops.select_format(m, b_r=32) == "ellpack_r"
    assert ops.select_format(m, b_r=32, x_tiles=4) in ("sell", "pjds")


def test_cache_key_normalizes_index_dtype(rng):
    _, m = _mk(rng, 96)
    d1 = ops.as_device(m, "pjds", b_r=32, index_dtype=np.int32)
    d2 = ops.as_device(m, "pjds", b_r=32, index_dtype="int32")
    d3 = ops.as_device(m, "pjds", b_r=32, index_dtype=np.dtype("int32"))
    assert d1 is d2 is d3


# ------------------------------------------------------- interpret default
def test_resolve_interpret_default_tracks_backend():
    on_tpu = jax.default_backend() == "tpu"
    assert ops.resolve_interpret(None) == (not on_tpu)
    assert ops.resolve_interpret(True) is True
    assert ops.resolve_interpret(False) is False


# ------------------------------------------------------------- distributed
def test_partition_compresses_per_device_slices(rng):
    # A 512-row global matrix split 4 ways: each slice spans n_loc = 128
    # local columns and a (2w+1)*n_loc ext buffer — both int16 territory
    # regardless of the global size.
    from repro.core import dist_spmv as D
    a, m = _mk(rng, 512, density=0.02)
    dist = D.partition_csr(m, 4, b_r=32)
    assert dist.loc_col.dtype == jnp.int16
    assert dist.rem_col.dtype == jnp.int16
    assert dist.loc_max_chunks >= 1 and dist.rem_max_chunks >= 1
    d32 = D.partition_csr(m, 4, b_r=32, index_dtype=np.int32)
    assert d32.loc_col.dtype == jnp.int32
