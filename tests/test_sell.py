"""SELL-C-sigma kernel vs the CSR reference: the acceptance sweep.

sigma in {b_r, 4*b_r, n_rows} x chunk_l in {8, 64}, f32, agreement to
1e-5 (relative to the result scale) on both the jnp ref and the Pallas
kernel (interpret mode), plus the structural invariants: window-local
inverse permutation, pJDS equivalence at sigma = n_rows, and alignment
checks.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import formats as F
from repro.kernels import ops

B_R = 32
N = 256


def _mk(rng, n=N, density=0.05):
    a = ((rng.random((n, n)) < density) * rng.standard_normal((n, n))
         ).astype(np.float32)
    return a, F.csr_from_dense(a)


@pytest.mark.parametrize("sigma", [B_R, 4 * B_R, N])
@pytest.mark.parametrize("chunk_l", [8, 64])
@pytest.mark.parametrize("backend", ["ref", "kernel"])
def test_sell_matvec_matches_csr_reference(rng, sigma, chunk_l, backend):
    a, m = _mk(rng)
    s = F.csr_to_sell(m, c=B_R, sigma=sigma, diag_align=chunk_l,
                      permuted_cols=False)
    dev = ops.to_device_sell(s, chunk_l=chunk_l)
    x = rng.standard_normal(N).astype(np.float32)
    truth = a.astype(np.float64) @ x          # == CSR reference m.matvec(x)
    y = np.asarray(ops.sell_matvec(dev, jnp.asarray(x), backend=backend))[:N]
    scale = max(np.abs(truth).max(), 1.0)
    np.testing.assert_allclose(y / scale, truth / scale, atol=1e-5)


def test_sell_output_is_original_order_no_host_permutation(rng):
    """The fused unpermute means y needs no post-processing at all."""
    a, m = _mk(rng)
    s = F.csr_to_sell(m, c=B_R, sigma=4 * B_R, permuted_cols=False)
    dev = ops.to_device_sell(s)
    x = rng.standard_normal(N).astype(np.float32)
    y_ref = np.asarray(ops.sell_matvec(dev, jnp.asarray(x), backend="ref"))
    y_ker = np.asarray(ops.sell_matvec(dev, jnp.asarray(x), backend="kernel"))
    np.testing.assert_allclose(y_ker, y_ref, atol=1e-4, rtol=1e-4)
    # padding rows (>= N) contribute zeros
    assert np.all(y_ref[N:] == 0)


@pytest.mark.parametrize("sigma", [B_R, 2 * B_R, 4 * B_R])
def test_inverse_permutation_is_window_local(rng, sigma):
    _, m = _mk(rng)
    s = F.csr_to_sell(m, c=B_R, sigma=sigma, permuted_cols=False)
    inv = np.asarray(s.pjds.inv_perm)
    assert np.abs(inv - np.arange(len(inv))).max() < sigma


def test_sigma_full_reduces_to_pjds(rng):
    _, m = _mk(rng)
    s = F.csr_to_sell(m, c=B_R, sigma=N, permuted_cols=False)
    p = F.csr_to_pjds(m, b_r=B_R, permuted_cols=False)
    assert F.storage_elements(s) == F.storage_elements(p)
    np.testing.assert_array_equal(np.asarray(s.pjds.perm), np.asarray(p.perm))


def test_storage_monotone_in_sigma(rng):
    """A bigger sort window never pads more."""
    _, m = _mk(rng, density=0.08)
    elems = [F.storage_elements(F.csr_to_sell(m, c=B_R, sigma=s,
                                              permuted_cols=False))
             for s in (B_R, 2 * B_R, 4 * B_R, N)]
    assert all(a >= b for a, b in zip(elems, elems[1:]))


def test_to_device_sell_chunk_mismatch_raises(rng):
    _, m = _mk(rng)
    s = F.csr_to_sell(m, c=B_R, sigma=B_R, diag_align=8,
                      permuted_cols=False)
    with pytest.raises(ValueError):
        ops.to_device_sell(s, chunk_l=16)   # 16 doesn't divide blocks of 8


def test_bf16_sell_accumulates_f32(rng):
    _, m = _mk(rng, density=0.1)
    s = F.csr_to_sell(m, c=B_R, sigma=4 * B_R, permuted_cols=False)
    dev = ops.to_device_sell(s, dtype=jnp.bfloat16)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(N)
                    .astype(np.float32)).astype(jnp.bfloat16)
    y_ref = ops.sell_matvec(dev, x, backend="ref")
    y_ker = ops.sell_matvec(dev, x, backend="kernel")
    assert y_ref.dtype == jnp.float32
    assert y_ker.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               atol=1e-2, rtol=1e-2)
