"""CMRS format: converter exactness, device refs, dispatch membership,
and the tuner search-space entries (DESIGN.md §13)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import formats as F, matrices as M
from repro.kernels import ops
from repro.tune.space import Candidate, enumerate_candidates, price_candidate


def _hub_matrix(rng, n=300):
    """A few huge rows over a sparse background: the padding-hostile
    shape where CMRS's dense packing wins every blocked format."""
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        a[i, rng.integers(0, n, size=3)] = rng.standard_normal(3)
    for i in rng.integers(0, n, size=4):
        a[i, :] = rng.standard_normal(n)
    np.fill_diagonal(a, np.arange(1, n + 1, dtype=np.float32))
    return a, F.csr_from_dense(a)


def test_cmrs_dense_roundtrip(rng):
    a, m = _hub_matrix(rng)
    for b_r, da in ((32, 8), (128, 16)):
        c = F.csr_to_cmrs(m, b_r=b_r, diag_align=da)
        np.testing.assert_array_equal(F.cmrs_to_dense(c), a)


def test_cmrs_estimate_matches_storage(rng):
    _, m = _hub_matrix(rng)
    rl = m.row_lengths()
    for b_r, da in ((32, 8), (64, 8), (128, 16)):
        c = F.csr_to_cmrs(m, b_r=b_r, diag_align=da)
        assert F.storage_elements(c) == \
            F.estimate_storage_elements(rl, "cmrs", b_r, da)


def test_cmrs_padding_invariant(rng):
    _, m = _hub_matrix(rng)
    c = F.csr_to_cmrs(m, b_r=32, diag_align=8)
    F.assert_padding_invariant(c)     # raises on violation
    bad = F.CMRSMatrix(
        val=c.val, col_idx=c.col_idx,
        row_in_strip=np.where(c.val == 0, 2, c.row_in_strip).astype(np.int8),
        strip_start=c.strip_start, strip_len=c.strip_len,
        strip_nnz=c.strip_nnz, shape=c.shape, b_r=c.b_r,
        n_rows_pad=c.n_rows_pad)
    if np.any(bad.row_in_strip != c.row_in_strip):
        with pytest.raises(AssertionError):
            F.assert_padding_invariant(bad)


def test_cmrs_matvec_matches_dense(rng):
    a, m = _hub_matrix(rng)
    sd = ops.as_device(m, "cmrs")
    x = rng.standard_normal(m.shape[1]).astype(np.float32)
    truth = a.astype(np.float64) @ x
    y = np.asarray(sd.matvec(jnp.asarray(x), backend="ref"), np.float64)
    np.testing.assert_allclose(y, truth, atol=1e-3 * np.abs(truth).max())


def test_cmrs_rmatvec_and_matmat(rng):
    a, m = _hub_matrix(rng)
    sd = ops.as_device(m, "cmrs")
    k = 3
    xs = rng.standard_normal((m.shape[1], k)).astype(np.float32)
    ym = np.asarray(sd.matmat(jnp.asarray(xs)), np.float64)
    np.testing.assert_allclose(ym, a.astype(np.float64) @ xs,
                               atol=1e-3 * np.abs(a).max() * np.sqrt(a.shape[0]))
    y = rng.standard_normal(m.shape[0]).astype(np.float32)
    zt = np.asarray(sd.rmatvec(jnp.asarray(y)), np.float64)
    truth_t = a.T.astype(np.float64) @ y
    np.testing.assert_allclose(zt, truth_t,
                               atol=1e-3 * max(np.abs(truth_t).max(), 1.0))


def test_cmrs_diagonal(rng):
    a, m = _hub_matrix(rng)
    from repro.core.operator import operator
    op = operator(m, format="cmrs")
    np.testing.assert_allclose(np.asarray(op.diagonal()), np.diag(a),
                               rtol=1e-6)


def test_select_format_offers_cmrs(rng):
    _, m = _hub_matrix(rng)
    pick = ops.select_format(m)
    assert pick == "cmrs"


def test_select_format_still_prefers_ell_for_uniform():
    m = M.poisson_2d(24, 24)
    assert ops.select_format(m) == "ellpack_r"


def test_cmrs_in_tuner_space(rng):
    _, m = _hub_matrix(rng)
    cands = enumerate_candidates(m)
    cm = [c for c in cands if c.fmt == "cmrs"]
    assert cm, "cmrs missing from the tuner search space"
    for c in cm[:3]:
        assert price_candidate(m, c) > 0


def test_cmrs_candidate_builds_through_as_device(rng):
    _, m = _hub_matrix(rng)
    c = Candidate(fmt="cmrs", b_r=32, chunk_l=8)
    sd = ops.as_device(m, **c.build_kwargs())
    assert sd.fmt == "cmrs"
    x = jnp.asarray(rng.standard_normal(m.shape[1]).astype(np.float32))
    y = sd.matvec(x, backend="ref")
    assert y.shape == (m.shape[0],)


def test_cmrs_empty_rows_and_tiny(rng):
    # all-empty strips, strip count 1, n not a multiple of b_r
    a = np.zeros((70, 70), np.float32)
    a[0, 3] = 2.0
    a[69, 0] = -1.0
    m = F.csr_from_dense(a)
    c = F.csr_to_cmrs(m, b_r=32, diag_align=8)
    np.testing.assert_array_equal(F.cmrs_to_dense(c), a)
    sd = ops.as_device(m, "cmrs", b_r=32)
    x = rng.standard_normal(70).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(sd.matvec(jnp.asarray(x), backend="ref")),
        a @ x, atol=1e-5)
