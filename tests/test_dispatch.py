"""Unified dispatch layer: operator(a, format="auto") @ x property tests.

Three structurally different sparsity patterns (banded, power-law,
uniform-random) must all produce the dense-reference answer through the
auto-dispatched path; the chosen format must be deterministic for a
fixed matrix; explicit formats must agree with each other; and the
conversion cache must hand back the same device representation.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import formats as F
from repro.core.operator import operator
from repro.kernels import ops

B_R = 32


def _banded(rng, n, bw=7):
    a = rng.standard_normal((n, n))
    d = np.abs(np.subtract.outer(np.arange(n), np.arange(n)))
    return np.where(d <= bw, a, 0.0).astype(np.float32)


def _power_law(rng, n):
    rl = np.clip(rng.zipf(1.7, size=n), 1, max(n // 4, 2))
    a = np.zeros((n, n), np.float32)
    for i in range(n):
        cols = rng.integers(0, n, size=rl[i])
        a[i, cols] = rng.standard_normal(len(cols))
    return a


def _uniform(rng, n, density=0.08):
    return (((rng.random((n, n)) < density)
             * rng.standard_normal((n, n))).astype(np.float32))


_PATTERNS = {"banded": _banded, "powerlaw": _power_law, "uniform": _uniform}


def _check_auto(a):
    m = F.csr_from_dense(a)
    rng = np.random.default_rng(1)
    x = rng.standard_normal(a.shape[1]).astype(np.float32)
    y = np.asarray(operator(m, format="auto", b_r=B_R) @ x)
    truth = a.astype(np.float64) @ x
    scale = max(np.abs(truth).max(), 1.0)
    np.testing.assert_allclose(y / scale, truth / scale, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       n=st.sampled_from([48, 96, 160, 224]),
       pattern=st.sampled_from(sorted(_PATTERNS)))
def test_auto_matches_dense_reference(seed, n, pattern):
    rng = np.random.default_rng(seed)
    _check_auto(_PATTERNS[pattern](rng, n))


@pytest.mark.parametrize("pattern", sorted(_PATTERNS))
def test_chosen_format_is_deterministic(rng, pattern):
    a = _PATTERNS[pattern](rng, 192)
    m = F.csr_from_dense(a)
    first = ops.select_format(m, b_r=B_R)
    assert all(ops.select_format(m, b_r=B_R) == first for _ in range(3))
    # the converted representation reports the same format
    assert ops.as_device(m, "auto", b_r=B_R).fmt == first
    # and an identical matrix built from the same dense array agrees
    assert ops.select_format(F.csr_from_dense(a), b_r=B_R) == first


@pytest.mark.parametrize("fmt", ["csr", "ellpack_r", "pjds", "sell"])
def test_explicit_formats_agree(rng, fmt):
    a = _uniform(rng, 160)
    m = F.csr_from_dense(a)
    x = rng.standard_normal(160).astype(np.float32)
    truth = a.astype(np.float64) @ x
    y = np.asarray(operator(m, format=fmt, b_r=B_R) @ x)
    scale = max(np.abs(truth).max(), 1.0)
    np.testing.assert_allclose(y / scale, truth / scale, atol=1e-5)


def test_kernel_backend_through_dispatch(rng):
    a = _uniform(rng, 128)
    m = F.csr_from_dense(a)
    x = rng.standard_normal(128).astype(np.float32)
    for fmt in ("ellpack_r", "pjds", "sell"):
        y_r = np.asarray(operator(m, format=fmt, b_r=B_R, backend="ref") @ x)
        y_k = np.asarray(operator(m, format=fmt, b_r=B_R,
                                   backend="kernel") @ x)
        np.testing.assert_allclose(y_k, y_r, atol=1e-4, rtol=1e-4)


def test_conversion_cache_reuses_device_rep(rng):
    m = F.csr_from_dense(_uniform(rng, 96))
    d1 = ops.as_device(m, "auto", b_r=B_R)
    d2 = ops.as_device(m, "auto", b_r=B_R)
    assert d1 is d2
    # different build params -> different entry (8 was the old default)
    d3 = ops.as_device(m, "auto", b_r=B_R, chunk_l=8)
    assert d3 is not d1
    # operator application goes through the same cache
    x = rng.standard_normal(96).astype(np.float32)
    operator(m, b_r=B_R) @ x
    assert ops.as_device(m, "auto", b_r=B_R) is d1


def test_dense_input_hits_conversion_cache(rng):
    """A dense ndarray is content-hashed: equal data (even a different
    array object) reuses one CSR conversion AND one device conversion —
    previously every dense call silently reconverted."""
    a = _uniform(rng, 96)
    d1 = ops.as_device(a, "auto", b_r=B_R)
    d2 = ops.as_device(a.copy(), "auto", b_r=B_R)   # equal bytes, new object
    assert d1 is d2
    # different content -> different entry
    b = a.copy()
    b[0, 0] += 1.0
    assert ops.as_device(b, "auto", b_r=B_R) is not d1
    # operator application over dense input rides the same cache
    x = rng.standard_normal(96).astype(np.float32)
    operator(a.copy(), b_r=B_R) @ x
    assert ops.as_device(a, "auto", b_r=B_R) is d1


def test_tiny_and_empty_fall_back_to_csr(rng):
    tiny = F.csr_from_dense(_uniform(rng, 16))
    assert ops.select_format(tiny, b_r=B_R) == "csr"
    empty = F.csr_from_dense(np.zeros((256, 256), np.float32))
    assert ops.select_format(empty, b_r=B_R) == "csr"
    x = np.ones(256, np.float32)
    assert np.all(np.asarray(operator(empty, b_r=B_R) @ x) == 0)


def test_non_square_dispatch(rng):
    a = (rng.random((96, 200)) < 0.1) * rng.standard_normal((96, 200))
    a = a.astype(np.float32)
    m = F.csr_from_dense(a)
    x = rng.standard_normal(200).astype(np.float32)
    truth = a.astype(np.float64) @ x
    for fmt in ("auto", "csr", "ellpack_r", "pjds", "sell"):
        y = np.asarray(operator(m, format=fmt, b_r=B_R) @ x)
        assert y.shape == (96,)
        np.testing.assert_allclose(y, truth, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       fmt=st.sampled_from(["ellpack_r", "pjds", "sell"]))
def test_storage_estimates_match_built_matrices(seed, fmt):
    """select_format prices formats from row lengths alone; the estimate
    must agree exactly with what the converters build."""
    rng = np.random.default_rng(seed)
    m = F.csr_from_dense(_uniform(rng, 160, density=0.1))
    rl = m.row_lengths()
    est = F.estimate_storage_elements(rl, fmt, b_r=B_R, diag_align=8,
                                      sigma=2 * B_R)
    if fmt == "ellpack_r":
        built = F.storage_elements(F.csr_to_ell(m, row_align=B_R,
                                                diag_align=8))
    elif fmt == "pjds":
        built = F.storage_elements(F.csr_to_pjds(m, b_r=B_R,
                                                 permuted_cols=False))
    else:
        built = F.storage_elements(F.csr_to_sell(m, c=B_R, sigma=2 * B_R,
                                                 permuted_cols=False))
    assert est == built


# --------------------------------------------------------------------------
# Deprecated pre-protocol shims
# --------------------------------------------------------------------------
def test_spmv_shim_warns_and_still_works(rng):
    """ops.spmv is a deprecated shim over the operator API: it must warn
    (pointing at operator / repro.solve) and keep computing correctly."""
    a = _uniform(rng, 120, density=0.08)
    m = F.csr_from_dense(a)
    x = rng.standard_normal(120).astype(np.float32)
    with pytest.warns(DeprecationWarning, match="operator"):
        y = np.asarray(ops.spmv(m, jnp.asarray(x)))
    np.testing.assert_allclose(y, a.astype(np.float64) @ x, atol=1e-4)


def test_operator_path_does_not_warn(rng):
    """The replacement API must be warning-free — otherwise every
    migrated caller would still see deprecation noise."""
    import warnings
    a = _uniform(rng, 96, density=0.1)
    m = F.csr_from_dense(a)
    x = jnp.asarray(rng.standard_normal(96).astype(np.float32))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        np.asarray(operator(m) @ x)
