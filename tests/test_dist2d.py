"""2-D block-partitioned distributed spMVM tests (subprocess, 8 host
devices): grid-shape x halo-flavour x mode parity against single-device
dense truth on non-divisible shapes, the partial-sum reduction epilogue,
pipeline double-buffering, degenerate (zero-row-device) partitions, the
transpose partition over swapped grids, and end-to-end ``repro.solve``.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.dist

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    import repro
    from repro.core import formats as F, dist_spmv as D
    from repro.core.operator import dist_operator
    from repro.launch.mesh import make_host_mesh

    out = {}
    n_dev = 8
    mesh = make_host_mesh(n_dev)
    rng = np.random.default_rng(0)

    # deliberately non-divisible: 323 = 17 * 19 rows, so every grid
    # shape pads and no device block is "naturally" aligned
    n = 323
    rows, cols = [], []
    for r in range(n):
        lo, hi = max(0, r - 40), min(n, r + 40)
        cand = np.arange(lo, hi)
        sel = cand[rng.random(len(cand)) < 0.3]
        rows += [r] * len(sel); cols += list(sel)
    m = F.csr_from_coo(np.array(rows), np.array(cols),
                       rng.standard_normal(len(rows)), (n, n))
    dense = F.csr_to_dense(m).astype(np.float64)

    shard = jax.NamedSharding(mesh, P("data"))
    shard2 = jax.NamedSharding(mesh, P("data", None))

    # every 8-device partition pads to the same global length
    # (padded_global_size depends on n_dev and b_r, not the grid)
    n_pad = D.padded_global_size(n, n_dev, 32)
    x_raw = rng.standard_normal(n_pad).astype(np.float32)
    X_raw = rng.standard_normal((n_pad, 3)).astype(np.float32)
    truth = dense @ x_raw[:n].astype(np.float64)
    scale = np.abs(truth).max()
    truth_mm = dense @ X_raw[:n].astype(np.float64)
    scale_mm = np.abs(truth_mm).max()

    # single-device reference (the 1-device "partition" pads less)
    mesh1 = make_host_mesh(1)
    d1 = D.partition_csr(m, 1, b_r=32)
    y1 = np.asarray(dist_operator(d1, mesh1).matvec(
        jnp.asarray(x_raw[:d1.n_global_pad])))
    out["err_single"] = float(np.abs(y1[:n] - truth).max() / scale)

    # grid x halo x mode parity, matvec + matmat
    errs = {}
    for grid in (None, (8, 1), (1, 8), (2, 4), (4, 2)):
        dist = D.partition_csr(m, n_dev, b_r=32, grid=grid)
        assert dist.n_global_pad == n_pad
        x = jax.device_put(jnp.asarray(x_raw), shard)
        X = jax.device_put(jnp.asarray(X_raw), shard2)
        g = "1d" if grid is None else f"{grid[0]}x{grid[1]}"
        errs[f"halo_w_{g}"] = int(dist.halo_w)
        errs[f"red_w_{g}"] = int(dist.red_w)
        for halo in ("gathered", "full"):
            for mode in ("vector", "overlap", "pipeline"):
                op = dist_operator(dist, mesh, mode=mode, halo=halo)
                y = np.asarray(jax.jit(op.matvec)(x))[:n]
                Y = np.asarray(jax.jit(op.matmat)(X))[:n]
                errs[f"{g}_{halo}_{mode}"] = max(
                    float(np.abs(y - truth).max() / scale),
                    float(np.abs(Y - truth_mm).max() / scale_mm))
    out["parity"] = errs

    # explicit halo_w widening: wider windows add only empty slots
    hw = {}
    meas = D.partition_csr(m, n_dev, b_r=32).halo_w
    for w in sorted({meas, meas + 1, 2}):
        dist = D.partition_csr(m, n_dev, b_r=32, halo_w=w)
        x = jax.device_put(jnp.asarray(x_raw), shard)
        y = np.asarray(jax.jit(dist_operator(dist, mesh).matvec)(x))[:n]
        hw[str(w)] = float(np.abs(y - truth).max() / scale)
    out["halo_w_sweep"] = hw
    out["halo_w_measured"] = int(meas)

    # halo_w == 0 on a block-diagonal matrix: no exchange at all
    blk = np.kron(np.eye(8, dtype=np.float32),
                  rng.standard_normal((32, 32)).astype(np.float32))
    mb = F.csr_from_dense(blk)
    db = D.partition_csr(mb, n_dev, b_r=32)
    out["halo_w_blockdiag"] = int(db.halo_w)
    xb = jax.device_put(jnp.asarray(
        rng.standard_normal(db.n_global_pad).astype(np.float32)), shard)
    yb = np.asarray(jax.jit(dist_operator(db, mesh).matvec)(xb))[:256]
    tb = blk.astype(np.float64) @ np.asarray(xb)[:256].astype(np.float64)
    out["err_blockdiag"] = float(np.abs(yb - tb).max()
                                 / max(np.abs(tb).max(), 1e-9))

    # degenerate partition: 2-D grid where trailing devices own only
    # padding (tiny matrix, wide grid) must build collective-compatible
    # empty programs and still be correct
    n_tiny = 40
    mt = F.csr_from_dense(
        (np.diag(np.full(n_tiny, 4.0))
         + np.diag(np.full(n_tiny - 1, -1.0), 1)
         + np.diag(np.full(n_tiny - 1, -1.0), -1)).astype(np.float32))
    for grid in ((4, 2), (2, 4)):
        dt = D.partition_csr(mt, n_dev, b_r=32, grid=grid)
        owners = dt.n_global_pad // max(dt.n_loc, 1)
        xt = jax.device_put(jnp.asarray(
            rng.standard_normal(dt.n_global_pad).astype(np.float32)), shard)
        for halo in ("gathered", "full"):
            yt = np.asarray(jax.jit(dist_operator(
                dt, mesh, halo=halo).matvec)(xt))[:n_tiny]
            tt = (F.csr_to_dense(mt).astype(np.float64)
                  @ np.asarray(xt)[:n_tiny].astype(np.float64))
            out[f"err_degenerate_{grid[0]}x{grid[1]}_{halo}"] = float(
                np.abs(yt - tt).max() / np.abs(tt).max())

    # transpose / rmatvec parity over a 2-D partition (swapped grid)
    op2 = dist_operator(m, mesh, b_r=32, grid=(2, 4))
    assert op2.dist.grid == (2, 4) and op2.t_dist.grid == (4, 2)
    x = jax.device_put(jnp.asarray(x_raw[:op2.dist.n_global_pad]), shard)
    yt = np.asarray(op2.rmatvec(x))[:n]
    truth_t = dense.T @ np.asarray(x)[:n].astype(np.float64)
    out["err_rmatvec_2d"] = float(np.abs(yt - truth_t).max()
                                  / np.abs(truth_t).max())
    out["err_diag_2d"] = float(np.abs(
        np.asarray(op2.diagonal())[:n] - np.diag(dense)).max())

    # end-to-end repro.solve(cg) on an SPD system over a 2-D grid
    spd = F.csr_from_dense((dense @ dense.T
                            + n * np.eye(n)).astype(np.float32))
    op_spd = dist_operator(spd, mesh, b_r=32, grid=(2, 4),
                           mode="pipeline")
    b = np.zeros(op_spd.dist.n_global_pad, np.float32)
    b[:n] = rng.standard_normal(n)
    bj = jax.device_put(jnp.asarray(b), shard)
    res = repro.solve(op_spd, bj, method="cg", maxiter=500, tol=1e-6)
    out["cg_res_2d"] = float(res.residual)
    out["cg_iters_2d"] = int(res.iters)

    # grid_shapes enumeration
    out["grid_shapes_8"] = D.grid_shapes(8)
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def r2d():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


TOL = 2e-5


def test_single_device_baseline(r2d):
    assert r2d["err_single"] < TOL


def test_grid_halo_mode_parity(r2d):
    """Every (grid, halo, mode) combination reproduces the dense truth
    on the non-divisible 323-row matrix, matvec and matmat."""
    bad = {k: v for k, v in r2d["parity"].items()
           if not k.startswith(("halo_w_", "red_w_")) and v > TOL}
    assert not bad, bad


def test_2d_grid_measures_both_couplings(r2d):
    """The banded matrix couples along rows, so 1-D measures a pure x
    halo; 2-D shapes move part (or, for (1,8), all) of the coupling
    into the partial-sum reduction."""
    p = r2d["parity"]
    assert p["halo_w_1d"] >= 1 and p["red_w_1d"] == 0
    assert p["halo_w_1x8"] == 0 and p["red_w_1x8"] >= 1
    assert p["red_w_2x4"] >= 1


def test_halo_w_widening_is_harmless(r2d):
    for err in r2d["halo_w_sweep"].values():
        assert err < TOL
    assert r2d["halo_w_measured"] >= 1


def test_block_diagonal_measures_zero_halo(r2d):
    assert r2d["halo_w_blockdiag"] == 0
    assert r2d["err_blockdiag"] < TOL


def test_degenerate_partition(r2d):
    """A 2-D grid over a matrix far smaller than the mesh leaves some
    devices owning only padding; the partition must still build (the
    edge-padded chunk maps degenerate to empty programs) and agree."""
    for grid in ("4x2", "2x4"):
        for halo in ("gathered", "full"):
            assert r2d[f"err_degenerate_{grid}_{halo}"] < TOL


def test_transpose_parity_2d(r2d):
    assert r2d["err_rmatvec_2d"] < TOL
    assert r2d["err_diag_2d"] < 1e-6


def test_solve_cg_2d_pipeline(r2d):
    assert r2d["cg_res_2d"] < 1e-5
    assert 0 < r2d["cg_iters_2d"] < 500


def test_grid_shapes_enumeration(r2d):
    got = [tuple(g) for g in r2d["grid_shapes_8"]]
    assert got[0] == (8, 1)
    assert set(got) == {(8, 1), (4, 2), (2, 4), (1, 8)}
