"""Format round-trips + memory accounting, incl. hypothesis property tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import formats as F
from repro.core import matrices as M


def random_sparse(rng, n, density=0.05, dtype=np.float64):
    a = (rng.random((n, n)) < density) * rng.standard_normal((n, n))
    return a.astype(dtype)


def test_csr_roundtrip(rng):
    a = random_sparse(rng, 200)
    m = F.csr_from_dense(a)
    assert np.array_equal(F.csr_to_dense(m), a)
    assert m.nnz == np.count_nonzero(a)


def test_csr_from_coo_duplicates():
    rows = np.array([0, 0, 1])
    cols = np.array([1, 1, 2])
    vals = np.array([2.0, 3.0, 4.0])
    m = F.csr_from_coo(rows, cols, vals, (3, 3))
    d = F.csr_to_dense(m)
    assert d[0, 1] == 5.0 and d[1, 2] == 4.0


def test_ell_roundtrip(rng):
    a = random_sparse(rng, 150)
    m = F.csr_from_dense(a)
    e = F.csr_to_ell(m, row_align=32, diag_align=8)
    assert np.allclose(F.ell_to_dense(e), a)
    assert e.val.shape[0] % 8 == 0 and e.n_rows_pad % 32 == 0


def test_pjds_roundtrip_and_sort(rng):
    a = random_sparse(rng, 200)
    m = F.csr_from_dense(a)
    p = F.csr_to_pjds(m, b_r=32)
    assert np.allclose(F.pjds_to_dense(p), a)
    # rows sorted by descending length
    assert np.all(np.diff(p.rowlen) <= 0)
    # blocks padded to block-local max
    for b in range(p.n_blocks):
        blk = p.rowlen[b * 32:(b + 1) * 32]
        assert p.block_len[b] >= blk.max()
        assert p.block_len[b] % 8 == 0


def test_pjds_permutation_consistency(rng):
    a = random_sparse(rng, 128, density=0.1)
    m = F.csr_from_dense(a)
    p = F.csr_to_pjds(m, b_r=32)
    x = rng.standard_normal(128)
    xp = p.permute(x)
    # permuted matvec equals original-basis matvec
    yp = np.zeros(p.n_rows_pad)
    for b in range(p.n_blocks):
        s, t = p.block_start[b], p.block_start[b + 1]
        for r in range(p.b_r):
            yp[b * 32 + r] = p.val[s:t, r] @ xp[p.col_idx[s:t, r]]
    assert np.allclose(p.unpermute(yp), a @ x)


def test_sell_matches_pjds_when_sigma_full(rng):
    a = random_sparse(rng, 96)
    m = F.csr_from_dense(a)
    s = F.csr_to_sell(m, c=32, sigma=96)
    p = F.csr_to_pjds(m, b_r=32)
    assert F.storage_elements(s) == F.storage_elements(p)
    assert np.allclose(F.sell_to_dense(s), a)


def test_paper_worst_case_bound():
    """Paper §2.1: one full row + singletons -> ELLPACK stores N*N,
    pJDS stores <= (b_r+1)*N - b_r."""
    n, br = 256, 32
    a = np.zeros((n, n))
    a[0, :] = 1.0
    a[1:, 0] = 1.0
    m = F.csr_from_dense(a)
    ell = F.csr_to_ell(m, row_align=br, diag_align=1)
    pj = F.csr_to_pjds(m, b_r=br, diag_align=1)
    assert F.storage_elements(ell) == n * n
    assert F.storage_elements(pj) <= (br + 1) * n - br
    assert F.data_reduction_vs_ellpack(m, b_r=br) > 0.8


def test_constant_row_length_no_overhead(rng):
    """Paper §2.1: constant row length -> neither format has overhead."""
    n, k = 128, 8
    a = np.zeros((n, n))
    for i in range(n):
        a[i, (np.arange(k) * 7 + i) % n] = rng.standard_normal(k)
    m = F.csr_from_dense(a)
    ell = F.csr_to_ell(m, row_align=32, diag_align=8)
    pj = F.csr_to_pjds(m, b_r=32, diag_align=8)
    assert F.storage_elements(ell) == F.storage_elements(pj) == n * k


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(8, 120),
    density=st.floats(0.01, 0.5),
    b_r=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2 ** 16),
)
def test_pjds_roundtrip_property(n, density, b_r, seed):
    rng = np.random.default_rng(seed)
    a = (rng.random((n, n)) < density) * rng.standard_normal((n, n))
    m = F.csr_from_dense(a)
    p = F.csr_to_pjds(m, b_r=b_r)
    assert np.allclose(F.pjds_to_dense(p), a, atol=1e-12)
    # invariant: pJDS never stores more padded elements than ELLPACK
    ell = F.csr_to_ell(m, row_align=b_r, diag_align=8)
    assert F.storage_elements(p) <= F.storage_elements(ell)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), sigma_mult=st.sampled_from([1, 2, 4]))
def test_sell_roundtrip_property(seed, sigma_mult):
    rng = np.random.default_rng(seed)
    n = 96
    a = (rng.random((n, n)) < 0.08) * rng.standard_normal((n, n))
    m = F.csr_from_dense(a)
    s = F.csr_to_sell(m, c=16, sigma=16 * sigma_mult)
    assert np.allclose(F.sell_to_dense(s), a, atol=1e-12)


@pytest.mark.parametrize("name", list(M.TEST_MATRICES))
def test_generators_match_published_stats(name):
    m = M.make_test_matrix(name, scale=0.01 if name in ("HMEp", "sAMG", "UHBR")
                           else 0.05)
    published = M._PUBLISHED[name]["n_nzr"]
    assert 0.4 * published <= m.n_nzr <= 1.8 * published
    # paper Table 1: pJDS saves memory on every test matrix
    red = F.data_reduction_vs_ellpack(m, b_r=32)
    assert red >= 0.0


def test_dlr2_has_dense_blocks():
    m = M.dlr2(scale=0.05)
    d = F.csr_to_dense(m)
    # sample some 5x5 blocks: a block containing a nonzero is mostly dense
    hits = 0
    for i in range(0, 200, 5):
        blk = d[i:i + 5, i:i + 5]
        if np.count_nonzero(blk) > 0:
            assert np.count_nonzero(blk) == 25
            hits += 1
    assert hits > 0


def test_csr_transpose_rows_sorted_no_duplicates(rng):
    """The audited invariant behind csr_transpose's
    sum_duplicates=False: csr_from_coo lexsorts BEFORE the dedup
    branch, so within-row columns come out sorted on both paths."""
    d = (rng.random((80, 50)) < 0.15) * rng.standard_normal((80, 50))
    m = F.csr_from_dense(d)
    mt = F.csr_transpose(m)
    _, report = F.validate_csr(mt)         # raises on unsorted/dup rows
    assert report.ok
    np.testing.assert_array_equal(F.csr_to_dense(mt), d.T)


def test_csr_from_coo_no_dedup_still_sorted(rng):
    rows = rng.integers(0, 30, size=200)
    cols = rng.integers(0, 30, size=200)
    vals = rng.standard_normal(200)
    # drop duplicates so sum_duplicates=False is legal, shuffle hard
    key = rows * 30 + cols
    _, first = np.unique(key, return_index=True)
    rows, cols, vals = rows[first], cols[first], vals[first]
    sh = rng.permutation(len(rows))
    m = F.csr_from_coo(rows[sh], cols[sh], vals[sh], shape=(30, 30),
                       sum_duplicates=False)
    _, report = F.validate_csr(m)
    assert report.ok
