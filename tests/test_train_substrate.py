"""Optimizer, schedules, ZeRO-1 spec logic, train loop + resume."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.train.optimizer import AdamW, global_norm, zero1_axis
from repro.train.schedules import wsd, cosine, constant


def test_adamw_reduces_quadratic():
    opt = AdamW(lr_fn=constant(0.1), weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, info = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert int(state.step) == 200


def test_grad_clip():
    opt = AdamW(lr_fn=constant(0.1), grad_clip=1.0)
    g = {"w": jnp.full((100,), 10.0)}
    assert float(global_norm(g)) > 1.0
    p = {"w": jnp.zeros((100,))}
    s = opt.init(p)
    _, _, info = opt.update(g, s, p)
    assert float(info["grad_norm"]) == pytest.approx(100.0, rel=1e-3)


def test_master_weights_float32():
    opt = AdamW(lr_fn=constant(1e-2))
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state.master["w"].dtype == jnp.float32
    new_p, new_s, _ = opt.update({"w": jnp.ones((4,), jnp.bfloat16)},
                                 state, params)
    assert new_p["w"].dtype == jnp.bfloat16
    assert new_s.master["w"].dtype == jnp.float32


def test_wsd_schedule_phases():
    fn = wsd(1.0, warmup=10, stable=20, decay=10, final_frac=0.1)
    assert float(fn(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(fn(jnp.asarray(15))) == pytest.approx(1.0)
    assert float(fn(jnp.asarray(29))) == pytest.approx(1.0)
    assert float(fn(jnp.asarray(40))) == pytest.approx(0.1, rel=1e-3)


def test_cosine_schedule():
    fn = cosine(1.0, warmup=10, total=110, final_frac=0.0)
    assert float(fn(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(fn(jnp.asarray(110))) == pytest.approx(0.0, abs=1e-6)


def test_zero1_axis_picks_largest_free_dim():
    axes = zero1_axis((1024, 512), ("model", None), ["data"],
                      {"data": 16, "model": 16})
    # dim0 taken by model -> dim1 gets data
    assert axes == ("model", ("data",))
    axes2 = zero1_axis((8,), (None,), ["data"], {"data": 16})
    assert axes2 == (None,)      # too small / not divisible -> replicated
    axes3 = zero1_axis((4096, 32), (None, None), ["pod", "data"],
                       {"pod": 2, "data": 16, "model": 16})
    assert axes3 == (("pod", "data"), None)


def test_watchdog_flags_stragglers():
    from repro.train.loop import Watchdog
    wd = Watchdog(straggler_factor=3.0)
    for i in range(10):
        wd.record(i, 0.1)
    assert wd.record(10, 1.0)            # 10x median -> straggler
    assert len(wd.stragglers) == 1
